"""Heterogeneous population scheme: a mixed CL/FL/SL fleet with
per-client radios trains end-to-end through the unchanged `Experiment`
runner, the per-client accounting in each `RoundReport` is consistent
with the fleet totals, and the spec/grouping plumbing holds its
invariants. Fleet dynamics (ISSUE 4): participation sampling is
seed-deterministic, deadline-dropped stragglers bill zero bits,
capture=True leaves the trajectory untouched, and CL members are
billed at init only. Degenerate (all-FL / all-SL) golden parity lives
in tests/test_scheme_parity.py."""
import jax
import numpy as np
import pytest

from repro.configs.base import WirelessConfig
from repro.schemes import (BATCH, ClientSpec, Experiment,
                           ParticipationPolicy, PopulationScheme, Radio,
                           build_scheme)

N_TRAIN, N_TEST = 2048, 512


def _mixed_clients(base):
    return [ClientSpec.fl(base, snr_db=20.0, name="fl-good"),
            ClientSpec.fl(base, snr_db=6.0, quant_bits=4, name="fl-weak"),
            ClientSpec.sl(base, snr_db=12.0, quant_bits=16, name="sl-mid"),
            ClientSpec.sl(base, snr_db=20.0, name="sl-good")]


def test_mixed_population_trains_with_per_client_accounting():
    """Acceptance: 2 FL + 2 SL clients with distinct SNRs through
    Experiment.run(), per-client bits/energy in every RoundReport."""
    base = WirelessConfig(mode="fl", quant_bits=8)
    exp = Experiment(build_scheme(base, clients=_mixed_clients(base)),
                     cycles=2, seed=0, n_train=N_TRAIN, n_test=N_TEST)
    res = exp.run()
    assert len(res.accuracy) == 2 and all(0.0 < a < 1.0
                                          for a in res.accuracy)
    assert res.user_flops > 0 and res.server_flops > 0
    for rep in exp.reports:
        names = [c.name for c in rep.clients]
        assert names == ["fl-good", "fl-weak", "sl-mid", "sl-good"]
        for c in rep.clients:
            assert c.bits > 0 and c.energy_j > 0 and c.n_tx > 0
            assert c.weight == pytest.approx(0.25)
        # fleet totals reassemble from the per-client breakdown
        assert rep.bits == pytest.approx(sum(c.bits for c in rep.clients))
        assert rep.energy_j == pytest.approx(
            sum(c.energy_j for c in rep.clients))
        assert rep.loss == pytest.approx(
            sum(c.loss * c.weight for c in rep.clients))
        # heterogeneity is visible in the bill: the Q4 FL client pays
        # half the Q8 one; the Q16 SL client pays double the Q8 one
        by = {c.name: c for c in rep.clients}
        assert by["fl-weak"].bits == by["fl-good"].bits / 2
        assert by["sl-mid"].bits == 2 * by["sl-good"].bits
    assert res.total_bits == pytest.approx(
        sum(r.bits for r in exp.reports))      # bits_normalizer == 1


def test_sample_count_weighting_and_custom_shards():
    """n_samples drives both the shard slicing and the aggregation
    weights (the SEMFED-style weighting rule)."""
    base = WirelessConfig(mode="fl", quant_bits=8)
    clients = [ClientSpec.fl(base, n_samples=3 * BATCH, name="big"),
               ClientSpec.sl(base, n_samples=BATCH, name="small")]
    exp = Experiment(build_scheme(base, clients=clients), cycles=1,
                     seed=0, n_train=N_TRAIN, n_test=N_TEST)
    exp.run()
    (rep,) = exp.reports
    by = {c.name: c for c in rep.clients}
    assert by["big"].weight == pytest.approx(0.75)
    assert by["small"].weight == pytest.approx(0.25)
    # FL client: J local epochs x 3 batches; SL client: 1 epoch x 1 batch
    assert by["big"].steps == base.local_steps * 3
    assert by["small"].steps == 1


def test_sl_client_local_epochs_are_honored():
    base = WirelessConfig(mode="fl", quant_bits=8)
    clients = [ClientSpec.fl(base, n_samples=BATCH, name="f"),
               ClientSpec.sl(base, local_epochs=2, n_samples=BATCH,
                             name="s")]
    exp = Experiment(build_scheme(base, clients=clients), cycles=1,
                     seed=0, n_train=N_TRAIN, n_test=N_TEST)
    exp.run()
    by = {c.name: c for c in exp.reports[0].clients}
    assert by["s"].steps == 2          # 2 epochs x 1 batch per epoch


def test_identical_fl_clients_share_one_stacked_upload():
    """FL clients with the same (radio, J, shard size) form one group —
    one fused stacked send — while a distinct-SNR client gets its own."""
    base = WirelessConfig(mode="fl", quant_bits=8)
    scheme = PopulationScheme(base, [
        ClientSpec.fl(base), ClientSpec.fl(base),
        ClientSpec.fl(base, snr_db=0.0)])
    from repro.schemes import corpus
    (xtr, ytr), _ = corpus(N_TRAIN, N_TEST, 0)
    scheme.init(0, xtr, ytr)
    assert [len(g.members) for g in scheme._groups] == [2, 1]
    assert scheme._groups[0].radio.snr_db == 20.0
    assert scheme._groups[1].radio.snr_db == 0.0


def test_eval_quantizer_is_order_independent():
    """The eval-time deployed function pins the fleet's highest-fidelity
    SL quantizer, so accuracy must not depend on SL client order."""
    base = WirelessConfig(mode="fl", quant_bits=8)
    a = PopulationScheme(base, [ClientSpec.sl(base, quant_bits=4),
                                ClientSpec.sl(base, quant_bits=16)])
    b = PopulationScheme(base, [ClientSpec.sl(base, quant_bits=16),
                                ClientSpec.sl(base, quant_bits=4)])
    assert a._sl_wcfg.quant_bits == b._sl_wcfg.quant_bits == 16


def test_client_spec_radio_overrides():
    base = WirelessConfig(mode="fl", quant_bits=8, snr_db=20.0)
    spec = ClientSpec.fl(base, snr_db=3.0, quant_bits=4, fading=False)
    assert spec.radio == Radio.from_wcfg(base, snr_db=3.0, quant_bits=4,
                                         fading=False)
    assert spec.radio.snr_db == 3.0 and spec.radio.quant_bits == 4
    assert spec.local_epochs == base.local_steps
    sl = ClientSpec.sl(base, snr_db=5.0)
    assert sl.wcfg.mode == "sl" and sl.local_epochs == 1


def test_population_validations():
    base = WirelessConfig(mode="fl")
    with pytest.raises(ValueError, match="at least one"):
        PopulationScheme(base, [])
    with pytest.raises(ValueError, match="compress_factor"):
        PopulationScheme(base, [
            ClientSpec.sl(base, compress_factor=4),
            ClientSpec.sl(base, compress_factor=2)])
    with pytest.raises(ValueError, match="median"):
        PopulationScheme(WirelessConfig(mode="fl", aggregate="median"),
                         [ClientSpec.fl(base)])
    with pytest.raises(ValueError, match="median"):
        # per-client override must be rejected too, not silently meaned
        PopulationScheme(base, [ClientSpec.fl(base, aggregate="median")])
    # participation-policy validation happens at construction
    with pytest.raises(ValueError, match="uniform-k"):
        PopulationScheme(base, [ClientSpec.fl(base)],
                         policy=ParticipationPolicy.uniform(2))
    with pytest.raises(ValueError, match="uniform-k"):
        PopulationScheme(base, [ClientSpec.fl(base)],
                         policy=ParticipationPolicy.uniform(0))
    with pytest.raises(ValueError, match="bernoulli"):
        PopulationScheme(base, [ClientSpec.fl(base)],
                         policy=ParticipationPolicy.bernoulli(0.0))
    with pytest.raises(ValueError, match="participation kind"):
        PopulationScheme(base, [ClientSpec.fl(base)],
                         policy=ParticipationPolicy("sometimes"))
    # shards that don't fit the corpus fail loudly at init, not in round
    scheme = PopulationScheme(base, [
        ClientSpec.fl(base, n_samples=N_TRAIN),
        ClientSpec.fl(base, n_samples=N_TRAIN)])
    from repro.schemes import corpus
    (xtr, ytr), _ = corpus(N_TRAIN, N_TEST, 0)
    with pytest.raises(ValueError, match="exceed"):
        scheme.init(0, xtr, ytr)


# ------------------------------------------------------- fleet dynamics
def test_explicit_full_policy_is_the_default_fleet():
    """policy=full() + deadline never hit + capture off IS the PR 3
    fleet: identical trajectory and identical billing (the degenerate
    path draws no policy RNG and slices no group state)."""
    base = WirelessConfig(mode="fl", quant_bits=8)
    plain = Experiment(build_scheme(base, clients=_mixed_clients(base)),
                       cycles=2, seed=0, n_train=N_TRAIN, n_test=N_TEST)
    fleet = Experiment(build_scheme(base, clients=_mixed_clients(base),
                                    policy=ParticipationPolicy.full(),
                                    deadline_s=1e9),
                       cycles=2, seed=0, n_train=N_TRAIN, n_test=N_TEST)
    rp, rf = plain.run(), fleet.run()
    np.testing.assert_array_equal(rp.accuracy, rf.accuracy)
    np.testing.assert_array_equal(rp.loss, rf.loss)
    assert rp.total_bits == rf.total_bits
    for a, b in zip(plain.reports, fleet.reports):
        assert [c.bits for c in a.clients] == [c.bits for c in b.clients]
        assert all(c.status == "ok" for c in b.clients)


def test_sampling_is_seed_deterministic():
    """uniform-k participation: the same seed draws the same subsets
    (same trajectory, same statuses), and the policy stream actually
    varies across cycles."""
    base = WirelessConfig(mode="fl", quant_bits=8)

    def run():
        exp = Experiment(build_scheme(
            base, clients=_mixed_clients(base),
            policy=ParticipationPolicy.uniform(2)),
            cycles=3, seed=7, n_train=N_TRAIN, n_test=N_TEST)
        res = exp.run()
        pattern = [tuple(c.status for c in rep.clients)
                   for rep in exp.reports]
        return res, pattern

    (ra, pa), (rb, pb) = run(), run()
    np.testing.assert_array_equal(ra.accuracy, rb.accuracy)
    assert pa == pb
    for pat in pa:                         # exactly k participate
        assert sum(s == "ok" for s in pat) == 2
        assert sum(s == "sampled_out" for s in pat) == 2
    assert len(set(pa)) > 1                # subsets vary across cycles
    # and the mask helper itself is a pure function of the key
    pol = ParticipationPolicy.uniform(2)
    k = jax.random.PRNGKey(3)
    np.testing.assert_array_equal(pol.active(k, 5), pol.active(k, 5))


def test_stragglers_bill_zero_bits():
    """A client whose estimated round time exceeds the deadline is
    dropped every round: zero bits / energy / steps, status
    "straggler", weight renormalized among the participants."""
    base = WirelessConfig(mode="fl", quant_bits=8)
    clients = [ClientSpec.fl(base, name="fast"),
               ClientSpec.fl(base, compute_s_per_step=1e6, name="slow"),
               ClientSpec.sl(base, name="sl-fast")]
    exp = Experiment(build_scheme(base, clients=clients,
                                  deadline_s=3600.0),
                     cycles=2, seed=0, n_train=N_TRAIN, n_test=N_TEST)
    exp.run()
    scheme = exp.scheme
    assert scheme.estimated_round_s(1) > 3600.0 > scheme.estimated_round_s(0)
    for rep in exp.reports:
        by = {c.name: c for c in rep.clients}
        slow = by["slow"]
        assert slow.status == "straggler"
        assert slow.bits == 0.0 and slow.energy_j == 0.0
        assert slow.steps == 0 and slow.n_tx == 0.0 and slow.weight == 0.0
        assert slow.est_round_s > 3600.0
        assert rep.metrics["n_stragglers"] == 1
        # participants' aggregation weights renormalize to 1
        assert sum(c.weight for c in rep.clients) == pytest.approx(1.0)
        assert by["fast"].bits > 0 and by["sl-fast"].bits > 0


def test_stochastic_deadline_varies_straggler_identity():
    """ROADMAP fleet follow-up: with deadline_jitter_sigma > 0 the
    compute term of the round estimate carries a per-(client, round)
    lognormal multiplier, so a borderline client straggles in SOME
    rounds rather than all — and the draw is seed-deterministic."""
    base = WirelessConfig(mode="fl", quant_bits=8)
    # compute estimate right AT the deadline: any jitter tips it
    clients = [ClientSpec.fl(base, name="fast"),
               ClientSpec.fl(base, compute_s_per_step=120.0,
                             name="edge")]

    def statuses(sigma, seed=0, cycles=6):
        # det. estimate ~1201s (10 steps x 120s + ~1s comm) < 1250s
        # deadline; the lognormal multiplier tips it ~half the rounds
        scheme = build_scheme(base, clients=clients, deadline_s=1250.0,
                              deadline_jitter_sigma=sigma)
        exp = Experiment(scheme, cycles=cycles, seed=seed,
                         n_train=N_TRAIN, n_test=N_TEST)
        exp.run()
        return [{c.name: c.status for c in rep.clients}[("edge")]
                for rep in exp.reports], exp

    det, exp_det = statuses(0.0)
    # deterministic model: the edge client's fate is the same every round
    assert len(set(det)) == 1
    for rep in exp_det.reports:       # sigma=0 reports the exact estimate
        by = {c.name: c for c in rep.clients}
        assert by["edge"].est_round_s == exp_det.scheme.estimated_round_s(1)

    jit1, exp_jit = statuses(0.8)
    assert set(jit1) == {"ok", "straggler"}    # identity varies per round
    ests = [{c.name: c for c in rep.clients}["edge"].est_round_s
            for rep in exp_jit.reports]
    assert len(set(ests)) == len(ests)         # fresh draw every round
    # seed-determinism: the same seed replays the same straggler pattern
    jit2, _ = statuses(0.8)
    assert jit1 == jit2
    # stragglers still bill zero
    for rep, s in zip(exp_jit.reports, jit1):
        edge = {c.name: c for c in rep.clients}["edge"]
        assert (edge.bits == 0.0) == (s == "straggler")


def test_deadline_jitter_validations():
    base = WirelessConfig(mode="fl", quant_bits=8)
    clients = [ClientSpec.fl(base), ClientSpec.fl(base)]
    with pytest.raises(ValueError, match=">= 0"):
        PopulationScheme(base, clients, deadline_s=10.0,
                         deadline_jitter_sigma=-0.1)
    with pytest.raises(ValueError, match="deadline_s"):
        PopulationScheme(base, clients, deadline_jitter_sigma=0.5)


def test_all_stragglers_is_a_zero_bit_round():
    """If nobody makes the deadline the round is empty: global model
    unchanged (constant accuracy), zero fleet bits."""
    base = WirelessConfig(mode="fl", quant_bits=8)
    clients = [ClientSpec.fl(base, compute_s_per_step=1e6, name=f"s{i}")
               for i in range(2)]
    exp = Experiment(build_scheme(base, clients=clients, deadline_s=1.0),
                     cycles=2, seed=0, n_train=N_TRAIN, n_test=N_TEST)
    res = exp.run()
    assert res.accuracy[0] == res.accuracy[1]      # nothing ever trains
    for rep in exp.reports:
        assert rep.bits == 0.0 and rep.steps == 0
        assert rep.metrics["n_active"] == 0


def test_population_capture_does_not_perturb_trajectory():
    """Acceptance: capture=True on a mixed fleet observes the SAME
    channel passes the round already makes — identical trajectory,
    non-empty FL delta and SL smashed-data observations."""
    base = WirelessConfig(mode="fl", quant_bits=8)
    cap = Experiment(build_scheme(base, clients=_mixed_clients(base),
                                  capture=True),
                     cycles=2, seed=0, n_train=N_TRAIN, n_test=N_TEST)
    ref = Experiment(build_scheme(base, clients=_mixed_clients(base)),
                     cycles=2, seed=0, n_train=N_TRAIN, n_test=N_TEST)
    rc, rr = cap.run(), ref.run()
    np.testing.assert_array_equal(rc.accuracy, rr.accuracy)
    np.testing.assert_array_equal(rc.loss, rr.loss)
    assert rc.total_bits == rr.total_bits
    # one delta stack per (round, radio group): 2 rounds x 2 FL groups,
    # covering both FL clients each round
    assert len(rc.captures["deltas"]) == 4
    assert sum(d.shape[0] for d in rc.captures["deltas"]) == 4
    assert len(rc.captures["smashed"]) >= 1        # reconstruction study
    assert rc.captures["smashed"][0].shape[0] == BATCH
    assert not rr.captures


def test_cl_members_are_billed_at_init_only():
    """A ClientSpec.cl member's corpus crossing is billed once at init
    through its own radio; its rounds are radio-silent server-side
    epochs folded into the weighted aggregation."""
    base = WirelessConfig(mode="fl", quant_bits=8)
    clients = [ClientSpec.fl(base, name="f"),
               ClientSpec.cl(base, snr_db=5.0, name="c")]
    exp = Experiment(build_scheme(base, clients=clients, capture=True),
                     cycles=2, seed=0, n_train=N_TRAIN, n_test=N_TEST)
    res = exp.run()
    from repro.core.centralized import token_bits
    from repro.schemes import CFG
    shard = N_TRAIN // 2
    want = shard * 30 * token_bits(CFG.vocab_size) + shard  # + 1b labels
    assert exp.init_delivery.bits == want
    for rep in exp.reports:
        by = {c.name: c for c in rep.clients}
        assert by["c"].paradigm == "cl"
        assert by["c"].bits == 0.0 and by["c"].energy_j == 0.0
        assert by["c"].steps > 0                   # it DID train
        assert by["c"].weight == pytest.approx(0.5)
    # the 5 dB upload corrupted token ids (the paper's CL failure mode)
    (rx,), (orig,) = (exp.scheme.captures["cl_received"],
                      exp.scheme.captures["cl_original"])
    assert (rx != orig).mean() > 0.01
    assert res.total_bits == pytest.approx(
        exp.init_delivery.bits + sum(r.bits for r in exp.reports))


def test_cl_member_straggler_exempt_and_sl_deadline():
    """CL members never straggle (no round radio); an SL client's
    comm-bound estimate follows bits / rate."""
    base = WirelessConfig(mode="fl", quant_bits=8)
    clients = [ClientSpec.cl(base, compute_s_per_step=1e6, name="c"),
               ClientSpec.sl(base, name="s")]
    scheme = build_scheme(base, clients=clients, deadline_s=3600.0)
    exp = Experiment(scheme, cycles=1, seed=0, n_train=N_TRAIN,
                     n_test=N_TEST)
    exp.run()
    by = {c.name: c for c in exp.reports[0].clients}
    assert by["c"].status == "ok" and by["c"].steps > 0
    assert scheme.estimated_round_s(0) == 0.0  # no deadline model for CL
    # deadline model: SL estimate = steps * bits_per_step / rate
    from repro.schemes.split import sl_bits_per_step
    spec = scheme.clients[1]
    steps = (N_TRAIN // 2) // BATCH
    want = steps * sl_bits_per_step(spec.wcfg, 8) / spec.radio.rate_bps()
    assert scheme.estimated_round_s(1) == pytest.approx(want)
