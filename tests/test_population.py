"""Heterogeneous population scheme: a mixed FL/SL fleet with per-client
radios trains end-to-end through the unchanged `Experiment` runner, the
per-client accounting in each `RoundReport` is consistent with the
fleet totals, and the spec/grouping plumbing holds its invariants.
Degenerate (all-FL / all-SL) golden parity lives in
tests/test_scheme_parity.py."""
import numpy as np
import pytest

from repro.configs.base import WirelessConfig
from repro.schemes import (BATCH, ClientSpec, Experiment,
                           PopulationScheme, Radio, build_scheme)

N_TRAIN, N_TEST = 2048, 512


def _mixed_clients(base):
    return [ClientSpec.fl(base, snr_db=20.0, name="fl-good"),
            ClientSpec.fl(base, snr_db=6.0, quant_bits=4, name="fl-weak"),
            ClientSpec.sl(base, snr_db=12.0, quant_bits=16, name="sl-mid"),
            ClientSpec.sl(base, snr_db=20.0, name="sl-good")]


def test_mixed_population_trains_with_per_client_accounting():
    """Acceptance: 2 FL + 2 SL clients with distinct SNRs through
    Experiment.run(), per-client bits/energy in every RoundReport."""
    base = WirelessConfig(mode="fl", quant_bits=8)
    exp = Experiment(build_scheme(base, clients=_mixed_clients(base)),
                     cycles=2, seed=0, n_train=N_TRAIN, n_test=N_TEST)
    res = exp.run()
    assert len(res.accuracy) == 2 and all(0.0 < a < 1.0
                                          for a in res.accuracy)
    assert res.user_flops > 0 and res.server_flops > 0
    for rep in exp.reports:
        names = [c.name for c in rep.clients]
        assert names == ["fl-good", "fl-weak", "sl-mid", "sl-good"]
        for c in rep.clients:
            assert c.bits > 0 and c.energy_j > 0 and c.n_tx > 0
            assert c.weight == pytest.approx(0.25)
        # fleet totals reassemble from the per-client breakdown
        assert rep.bits == pytest.approx(sum(c.bits for c in rep.clients))
        assert rep.energy_j == pytest.approx(
            sum(c.energy_j for c in rep.clients))
        assert rep.loss == pytest.approx(
            sum(c.loss * c.weight for c in rep.clients))
        # heterogeneity is visible in the bill: the Q4 FL client pays
        # half the Q8 one; the Q16 SL client pays double the Q8 one
        by = {c.name: c for c in rep.clients}
        assert by["fl-weak"].bits == by["fl-good"].bits / 2
        assert by["sl-mid"].bits == 2 * by["sl-good"].bits
    assert res.total_bits == pytest.approx(
        sum(r.bits for r in exp.reports))      # bits_normalizer == 1


def test_sample_count_weighting_and_custom_shards():
    """n_samples drives both the shard slicing and the aggregation
    weights (the SEMFED-style weighting rule)."""
    base = WirelessConfig(mode="fl", quant_bits=8)
    clients = [ClientSpec.fl(base, n_samples=3 * BATCH, name="big"),
               ClientSpec.sl(base, n_samples=BATCH, name="small")]
    exp = Experiment(build_scheme(base, clients=clients), cycles=1,
                     seed=0, n_train=N_TRAIN, n_test=N_TEST)
    exp.run()
    (rep,) = exp.reports
    by = {c.name: c for c in rep.clients}
    assert by["big"].weight == pytest.approx(0.75)
    assert by["small"].weight == pytest.approx(0.25)
    # FL client: J local epochs x 3 batches; SL client: 1 epoch x 1 batch
    assert by["big"].steps == base.local_steps * 3
    assert by["small"].steps == 1


def test_sl_client_local_epochs_are_honored():
    base = WirelessConfig(mode="fl", quant_bits=8)
    clients = [ClientSpec.fl(base, n_samples=BATCH, name="f"),
               ClientSpec.sl(base, local_epochs=2, n_samples=BATCH,
                             name="s")]
    exp = Experiment(build_scheme(base, clients=clients), cycles=1,
                     seed=0, n_train=N_TRAIN, n_test=N_TEST)
    exp.run()
    by = {c.name: c for c in exp.reports[0].clients}
    assert by["s"].steps == 2          # 2 epochs x 1 batch per epoch


def test_identical_fl_clients_share_one_stacked_upload():
    """FL clients with the same (radio, J, shard size) form one group —
    one fused stacked send — while a distinct-SNR client gets its own."""
    base = WirelessConfig(mode="fl", quant_bits=8)
    scheme = PopulationScheme(base, [
        ClientSpec.fl(base), ClientSpec.fl(base),
        ClientSpec.fl(base, snr_db=0.0)])
    from repro.schemes import corpus
    (xtr, ytr), _ = corpus(N_TRAIN, N_TEST, 0)
    scheme.init(0, xtr, ytr)
    assert [len(g.members) for g in scheme._groups] == [2, 1]
    assert scheme._groups[0].radio.snr_db == 20.0
    assert scheme._groups[1].radio.snr_db == 0.0


def test_eval_quantizer_is_order_independent():
    """The eval-time deployed function pins the fleet's highest-fidelity
    SL quantizer, so accuracy must not depend on SL client order."""
    base = WirelessConfig(mode="fl", quant_bits=8)
    a = PopulationScheme(base, [ClientSpec.sl(base, quant_bits=4),
                                ClientSpec.sl(base, quant_bits=16)])
    b = PopulationScheme(base, [ClientSpec.sl(base, quant_bits=16),
                                ClientSpec.sl(base, quant_bits=4)])
    assert a._sl_wcfg.quant_bits == b._sl_wcfg.quant_bits == 16


def test_client_spec_radio_overrides():
    base = WirelessConfig(mode="fl", quant_bits=8, snr_db=20.0)
    spec = ClientSpec.fl(base, snr_db=3.0, quant_bits=4, fading=False)
    assert spec.radio == Radio.from_wcfg(base, snr_db=3.0, quant_bits=4,
                                         fading=False)
    assert spec.radio.snr_db == 3.0 and spec.radio.quant_bits == 4
    assert spec.local_epochs == base.local_steps
    sl = ClientSpec.sl(base, snr_db=5.0)
    assert sl.wcfg.mode == "sl" and sl.local_epochs == 1


def test_population_validations():
    base = WirelessConfig(mode="fl")
    with pytest.raises(ValueError, match="at least one"):
        PopulationScheme(base, [])
    with pytest.raises(ValueError, match="compress_factor"):
        PopulationScheme(base, [
            ClientSpec.sl(base, compress_factor=4),
            ClientSpec.sl(base, compress_factor=2)])
    with pytest.raises(ValueError, match="median"):
        PopulationScheme(WirelessConfig(mode="fl", aggregate="median"),
                         [ClientSpec.fl(base)])
    with pytest.raises(ValueError, match="median"):
        # per-client override must be rejected too, not silently meaned
        PopulationScheme(base, [ClientSpec.fl(base, aggregate="median")])
    with pytest.raises(ValueError, match="capture"):
        PopulationScheme(base, [ClientSpec.fl(base)], capture=True)
    # shards that don't fit the corpus fail loudly at init, not in round
    scheme = PopulationScheme(base, [
        ClientSpec.fl(base, n_samples=N_TRAIN),
        ClientSpec.fl(base, n_samples=N_TRAIN)])
    from repro.schemes import corpus
    (xtr, ytr), _ = corpus(N_TRAIN, N_TEST, 0)
    with pytest.raises(ValueError, match="exceed"):
        scheme.init(0, xtr, ytr)
