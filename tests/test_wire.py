"""Packed-wire tests: packed output bit-exactly equals the per-leaf
reference path for identical keys, pack/unpack round-trips ragged
pytrees, and the payload accounting is a single consistent helper."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest
from _hyp import given, settings, strategies as st

from repro.core import channel as CH
from repro.core import federated as FED
from repro.core import quantization as Q
from repro.core import wire as W
from repro.configs.base import WirelessConfig

HS = settings(max_examples=10, deadline=None)


def _ragged_tree(seed=0):
    ks = jax.random.split(jax.random.PRNGKey(seed), 5)
    return {"w": jax.random.normal(ks[0], (17, 33)),
            "b": jax.random.normal(ks[1], (7,)),
            "scalar": jax.random.normal(ks[2], ()),
            "conv": jax.random.normal(ks[3], (3, 5, 2)),
            "big": jax.random.normal(ks[4], (41, 67))}


def _assert_tree_equal(a, b):
    for x, y in zip(jax.tree.leaves(a), jax.tree.leaves(b)):
        np.testing.assert_array_equal(np.asarray(x), np.asarray(y))


# ------------------------------------------------------- equivalence (exact)
@pytest.mark.parametrize("bits", [4, 8])
@pytest.mark.parametrize("fading", [True, False])
def test_packed_bit_exact_vs_per_leaf(bits, fading):
    """The fused one-shot pass and the per-leaf reference loop consume
    the same rand buffer and fades -> bit-identical received trees."""
    tree = _ragged_tree()
    key = jax.random.PRNGKey(42)
    packed = W.transmit_tree(key, tree, bits, 6.0, fading=fading,
                             impl="packed")
    per_leaf = W.transmit_tree(key, tree, bits, 6.0, fading=fading,
                               impl="per_leaf")
    _assert_tree_equal(packed, per_leaf)


@pytest.mark.parametrize("bits", [4, 8])
def test_kernel_bit_exact_vs_per_leaf(bits):
    """Pallas packed kernel (interpret mode) == per-leaf reference."""
    tree = _ragged_tree(1)
    key = jax.random.PRNGKey(7)
    kern = W.transmit_tree(key, tree, bits, 6.0, impl="kernel")
    per_leaf = W.transmit_tree(key, tree, bits, 6.0, impl="per_leaf")
    _assert_tree_equal(kern, per_leaf)


def test_stacked_bit_exact_vs_per_leaf():
    """FL-shaped transmit: [N, ...] leaves, per-(user, tensor) fades."""
    tree = jax.tree.map(lambda p: jnp.stack([p, 2 * p, 0.5 * p]),
                        _ragged_tree(2))
    key = jax.random.PRNGKey(3)
    for impl in ("packed", "kernel"):
        got = W.transmit_stacked(key, tree, 8, 6.0, impl=impl)
        ref = W.transmit_stacked(key, tree, 8, 6.0, impl="per_leaf")
        _assert_tree_equal(got, ref)


def test_packed_arq_bit_exact_vs_per_leaf():
    tree = _ragged_tree(4)
    key = jax.random.PRNGKey(11)
    a = W.transmit_tree(key, tree, 8, 0.0, arq_attempts=4, impl="packed")
    b = W.transmit_tree(key, tree, 8, 0.0, arq_attempts=4, impl="per_leaf")
    _assert_tree_equal(a, b)


# -------------------------------------------------------- int8 on-wire dtype
@pytest.mark.parametrize("bits", [4, 8])
def test_int8_wire_bit_exact_vs_float(bits):
    """The byte-codeword on-wire buffer must be a pure storage change:
    same codes, same flip mask, bit-identical received tree."""
    tree = _ragged_tree(6)
    key = jax.random.PRNGKey(13)
    f32 = W.transmit_tree(key, tree, bits, 6.0)
    i8 = W.transmit_tree(key, tree, bits, 6.0, wire_dtype="int8")
    _assert_tree_equal(f32, i8)
    stacked = jax.tree.map(lambda p: jnp.stack([p, 2 * p]), tree)
    f32 = W.transmit_stacked(key, stacked, bits, 6.0)
    i8 = W.transmit_stacked(key, stacked, bits, 6.0, wire_dtype="int8")
    _assert_tree_equal(f32, i8)


@pytest.mark.parametrize("bits", [4, 8])
def test_int8_wire_kernel_bit_exact(bits):
    """The Pallas kernel's uint8 codeword path must match the jnp int8
    path — and both the float32 reference — bit for bit at Q<=8."""
    tree = _ragged_tree(8)
    key = jax.random.PRNGKey(17)
    f32 = W.transmit_tree(key, tree, bits, 6.0, impl="kernel")
    i8k = W.transmit_tree(key, tree, bits, 6.0, impl="kernel",
                          wire_dtype="int8")
    i8j = W.transmit_tree(key, tree, bits, 6.0, wire_dtype="int8")
    _assert_tree_equal(f32, i8k)
    _assert_tree_equal(i8j, i8k)
    stacked = jax.tree.map(lambda p: jnp.stack([p, 2 * p]), tree)
    i8k = W.transmit_stacked(key, stacked, bits, 6.0, impl="kernel",
                             wire_dtype="int8")
    i8j = W.transmit_stacked(key, stacked, bits, 6.0, wire_dtype="int8")
    _assert_tree_equal(i8j, i8k)


@HS
@given(seed=st.integers(0, 2 ** 16), bits=st.integers(2, 8))
def test_int8_wire_kernel_property(seed, bits):
    """Property: any Q<=8 quantizer, any key — kernel int8 == jnp int8."""
    tree = {"w": jax.random.normal(jax.random.PRNGKey(seed), (9, 21)),
            "b": jax.random.normal(jax.random.PRNGKey(seed + 1), (5,))}
    key = jax.random.PRNGKey(seed + 2)
    kern = W.transmit_tree(key, tree, bits, 4.0, impl="kernel",
                           wire_dtype="int8")
    jnp_ = W.transmit_tree(key, tree, bits, 4.0, wire_dtype="int8")
    _assert_tree_equal(kern, jnp_)


def test_int8_wire_rejects_wide_codewords_and_other_impls():
    tree = _ragged_tree(6)
    key = jax.random.PRNGKey(13)
    with pytest.raises(ValueError, match="8-bit"):
        W.transmit_tree(key, tree, 16, 6.0, wire_dtype="int8")
    with pytest.raises(ValueError, match="packed"):
        W.transmit_tree(key, tree, 8, 6.0, wire_dtype="int8",
                        impl="per_leaf")


def test_radio_int8_wire_same_delivery():
    """Radio(wire_dtype="int8") delivers the identical payload and
    bills the identical bits as the float32 wire at Q8."""
    from repro.schemes.radio import Radio
    tree = _ragged_tree(7)
    key = jax.random.PRNGKey(21)
    a = Radio(quant_bits=8, snr_db=6.0).send_tree(key, tree)
    b = Radio(quant_bits=8, snr_db=6.0, wire_dtype="int8").send_tree(key,
                                                                     tree)
    _assert_tree_equal(a.payload, b.payload)
    assert a.bits == b.bits and a.n_tx == b.n_tx


def test_perfect_channel_is_per_tensor_quantization():
    tree = _ragged_tree(5)
    out = W.transmit_tree(jax.random.PRNGKey(0), tree, 8, 0.0, perfect=True)
    for x, y in zip(jax.tree.leaves(tree), jax.tree.leaves(out)):
        q, s = Q.quantize(x, 8)
        np.testing.assert_allclose(np.asarray(y),
                                   np.asarray(Q.dequantize(q, s)),
                                   atol=1e-6)


def test_low_snr_corrupts_high_snr_does_not():
    x = jax.random.normal(jax.random.PRNGKey(0), (64, 64))
    hi = W.transmit_tree(jax.random.PRNGKey(1), x, 8, 60.0, fading=False)
    assert float(jnp.max(jnp.abs(hi - x))) <= float(Q.scale_for(x, 8)) / 2 \
        + 1e-6
    lo = W.transmit_tree(jax.random.PRNGKey(1), x, 8, -10.0, fading=False)
    assert float(jnp.mean(jnp.abs(lo - x))) > 0.1


# ------------------------------------------------------ pack/unpack property
@HS
@given(seed=st.integers(0, 2 ** 16), n_leaves=st.integers(1, 6))
def test_pack_unpack_roundtrip_ragged(seed, n_leaves):
    rng = np.random.default_rng(seed)
    leaves = []
    for i in range(n_leaves):
        nd = int(rng.integers(0, 4))
        shape = tuple(int(rng.integers(1, 9)) for _ in range(nd))
        leaves.append(jnp.asarray(rng.standard_normal(shape),
                                  jnp.float32))
    tree = {f"leaf{i}": l for i, l in enumerate(leaves)}
    buf, plan = W.pack_tree(tree)
    assert buf.shape == (plan.n_rows, plan.cols)
    assert plan.n_rows % 8 == 0
    out = W.unpack_tree(buf, plan)
    _assert_tree_equal(tree, out)
    # manifest rows cover exactly the payload, in order
    for i in range(plan.n_packets):
        assert plan.rows[i] == -(-plan.sizes[i] // plan.cols)
    assert plan.row_start == tuple(
        int(np.cumsum((0,) + plan.rows[:-1])[i])
        for i in range(plan.n_packets))


# ------------------------------------------------------------- accounting
def test_payload_bits_helper_consistency():
    tree = _ragged_tree()
    n = sum(l.size for l in jax.tree.leaves(tree))
    got = W.payload_bits(tree, 8)
    assert isinstance(got, float) and got == n * 8
    # matches the per-tensor helper summed over leaves
    assert got == sum(Q.payload_bits(l, 8) for l in jax.tree.leaves(tree))
    # ARQ expectation scales the count analytically
    e = W.expected_arq_tx(attempts=4, min_f2=0.25)
    assert 1.0 < e < 4.0
    assert W.payload_bits(tree, 8, e) == pytest.approx(n * 8 * e)
    # degenerate cases collapse to one transmission
    assert W.expected_arq_tx(attempts=1) == 1.0
    assert W.expected_arq_tx(attempts=4, fading=False) == 1.0
    assert W.expected_arq_tx(attempts=4, perfect=True) == 1.0


def test_transmit_pytree_and_fedavg_accounting_agree():
    """Satellite: both hot paths report wire.payload_bits floats."""
    tree = {"a": jnp.ones((10, 10)), "b": jnp.ones((7,))}
    _, bits_tree = CH.transmit_pytree(jax.random.PRNGKey(0), tree, 8, 20.0)
    assert isinstance(bits_tree, float) and bits_tree == 107 * 8
    up = jax.tree.map(lambda p: jnp.stack([p, p, p]), tree)
    wcfg = WirelessConfig(mode="fl", quant_bits=8)
    _, bits_fl = FED.fedavg_through_channel(jax.random.PRNGKey(1), up, wcfg)
    assert isinstance(bits_fl, float) and bits_fl == 3 * 107 * 8


def test_fedavg_median_aggregate_still_works():
    tree = {"a": jnp.ones((6, 6))}
    up = jax.tree.map(lambda p: jnp.stack([p, 2 * p, 30 * p]), tree)
    wcfg = WirelessConfig(mode="fl", quant_bits=8, perfect_channel=True,
                          aggregate="median")
    synced, _ = FED.fedavg_through_channel(jax.random.PRNGKey(0), up, wcfg)
    med = jax.tree.leaves(synced)[0][0]
    # median of (1, 2, 30)*quant ~ 2 (robust to the outlier user)
    np.testing.assert_allclose(np.asarray(med), 2.0, atol=0.1)


# -------------------------------------------------------- int4 on-wire dtype
@pytest.mark.parametrize("bits", [2, 3, 4])
def test_int4_wire_bit_exact_vs_float(bits):
    """Two-codewords-per-byte packing must be a pure storage change:
    every Q<=4 crossing delivers bit-identical floats to the abstract
    float32 wire (the nibble XOR of the bit-flip mask factorizes —
    flips never carry across the nibble boundary)."""
    tree = _ragged_tree(8)
    key = jax.random.PRNGKey(21)
    i4 = W.transmit_tree(key, tree, bits, 6.0, wire_dtype="int4")
    f32 = W.transmit_tree(key, tree, bits, 6.0)
    _assert_tree_equal(i4, f32)
    stacked = jax.tree.map(lambda p: jnp.stack([p, 2 * p]), tree)
    i4 = W.transmit_stacked(key, stacked, bits, 6.0, wire_dtype="int4")
    f32 = W.transmit_stacked(key, stacked, bits, 6.0)
    _assert_tree_equal(i4, f32)


@pytest.mark.parametrize("bits", [2, 4])
def test_int4_wire_kernel_bit_exact(bits):
    """The Pallas kernel's nibble-codeword path == the jnp packed path
    (the kernel carries nibble values in uint8 containers; values are
    identical to the physically packed bytes)."""
    tree = _ragged_tree(9)
    key = jax.random.PRNGKey(22)
    i4k = W.transmit_tree(key, tree, bits, 6.0, impl="kernel",
                          wire_dtype="int4")
    i4j = W.transmit_tree(key, tree, bits, 6.0, wire_dtype="int4")
    _assert_tree_equal(i4k, i4j)
    stacked = jax.tree.map(lambda p: jnp.stack([p, 0.5 * p]), tree)
    i4k = W.transmit_stacked(key, stacked, bits, 6.0, impl="kernel",
                             wire_dtype="int4")
    i4j = W.transmit_stacked(key, stacked, bits, 6.0, wire_dtype="int4")
    _assert_tree_equal(i4k, i4j)


@given(seed=st.integers(0, 2**32 - 1), half_cols=st.integers(1, 64))
@HS
def test_nibble_pack_roundtrip_property(seed, half_cols):
    """Property: any uint4 codeword row of even length survives
    pack_nibbles -> unpack_nibbles exactly, and the packed buffer is
    half the size."""
    rng = np.random.default_rng(seed)
    code = jnp.asarray(rng.integers(0, 16, (3, 2 * half_cols)), jnp.int32)
    packed = Q.pack_nibbles(code)
    assert packed.dtype == jnp.uint8
    assert packed.shape == (3, half_cols)
    out = Q.unpack_nibbles(packed)
    np.testing.assert_array_equal(np.asarray(out), np.asarray(code))


def test_int4_payload_bits_halving():
    """int4 bills exactly half of int8 — and the same as the abstract
    float32 wire at Q=4 (the paper's convention already charges 4
    bits/elem there)."""
    tree = _ragged_tree()
    n = sum(l.size for l in jax.tree.leaves(tree))
    assert W.payload_bits(tree, 4, wire_dtype="int4") == n * 4
    assert W.payload_bits(tree, 4, wire_dtype="int8") == n * 8
    assert W.payload_bits(tree, 4, wire_dtype="int4") \
        == W.payload_bits(tree, 4, wire_dtype="int8") / 2
    assert W.payload_bits(tree, 4, wire_dtype="int4") \
        == W.payload_bits(tree, 4)
    assert W.wire_width("int4", 4) == 4
    assert W.wire_width("int8", 4) == 8
    assert W.wire_width("float32", 7) == 7


def test_int4_rejects_wide_codewords_and_other_impls():
    tree = _ragged_tree()
    key = jax.random.PRNGKey(0)
    with pytest.raises(ValueError, match="quant_bits"):
        W.transmit_tree(key, tree, 5, 6.0, wire_dtype="int4")
    with pytest.raises(ValueError, match="impl"):
        W.transmit_tree(key, tree, 4, 6.0, wire_dtype="int4",
                        impl="per_leaf")


# ------------------------------------------------- fused mean (FL collective)
@pytest.mark.parametrize("wire_dtype", ["float32", "int8", "int4"])
def test_stacked_mean_kernel_bitwise_matches_packed(wire_dtype):
    """The ONE-launch Pallas mean (user axis as the innermost grid dim,
    accumulated at the output block) is bitwise the jnp packed
    reference (scan-ordered weighted sum)."""
    tree = jax.tree.map(lambda p: jnp.stack([p, 2 * p, 0.5 * p]),
                        _ragged_tree(5))
    key = jax.random.PRNGKey(13)
    mk, dk = W.transmit_stacked_mean(key, tree, 4, 6.0, impl="kernel",
                                     wire_dtype=wire_dtype)
    mj, dj = W.transmit_stacked_mean(key, tree, 4, 6.0, impl="packed",
                                     wire_dtype=wire_dtype)
    _assert_tree_equal(mk, mj)
    assert int(dk["n_alive"]) == int(dj["n_alive"]) == 3


def test_stacked_mean_allclose_legacy_dequant_then_mean():
    """Same fades/rand/quantizer as transmit_stacked -> the fused mean
    is the legacy mean up to summation order (allclose, not bitwise —
    why wcfg.use_kernel is opt-in)."""
    tree = jax.tree.map(lambda p: jnp.stack([p, 2 * p, 0.5 * p]),
                        _ragged_tree(6))
    key = jax.random.PRNGKey(14)
    mean_tree, diag = W.transmit_stacked_mean(key, tree, 8, 6.0,
                                              impl="kernel")
    rx = W.transmit_stacked(key, tree, 8, 6.0, impl="packed")
    for got, ref in zip(jax.tree.leaves(mean_tree),
                        jax.tree.leaves(jax.tree.map(
                            lambda r: jnp.mean(r, axis=0), rx))):
        np.testing.assert_allclose(np.asarray(got), np.asarray(ref),
                                   rtol=2e-6, atol=1e-6)
    assert float(diag["n_tx"].sum()) == 3 * 5


def test_stacked_mean_erasures_drop_users():
    """Bounded-ARQ erasures: users with any erased packet carry zero
    weight; the erased mask and n_tx equal transmit_stacked's diag on
    the same key (one draw, two consumers)."""
    tree = jax.tree.map(lambda p: jnp.stack([p, p, p]), _ragged_tree(7))
    key = jax.random.PRNGKey(77)
    kw = dict(snr_db=-12.0, arq_attempts=2, arq_max_tx=2,
              arq_min_f2=0.9)
    mean_tree, diag = W.transmit_stacked_mean(key, tree, 8,
                                              impl="kernel", **kw)
    rx, ref_diag = W.transmit_stacked(key, tree, 8, return_diag=True,
                                      impl="packed", **kw)
    np.testing.assert_array_equal(np.asarray(diag["erased"]),
                                  np.asarray(ref_diag["erased"]))
    np.testing.assert_array_equal(np.asarray(diag["n_tx"]),
                                  np.asarray(ref_diag["n_tx"]))
    alive = ~np.asarray(ref_diag["erased"]).any(axis=1)
    assert int(diag["n_alive"]) == int(alive.sum())
    for leaf in jax.tree.leaves(mean_tree):
        assert np.isfinite(np.asarray(leaf)).all()
