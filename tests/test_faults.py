"""Fault-tolerant fleets: bounded ARQ erasures, Gilbert-Elliott burst
outages, quorum-gated aggregation, FaultPlan chaos schedules, and the
opt-in stochastic-rounding wire flag. Billing-algebra properties live
in tests/test_billing.py; kill-and-resume parity in tests/test_resume.py.
"""
import json

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs.base import WirelessConfig
from repro.core import quantization as Q
from repro.core import wire as W
from repro.schemes import (ClientSpec, Experiment, FaultPlan,
                           FederatedScheme, Radio, build_scheme)

N_TRAIN, N_TEST = 2048, 512


# ----------------------------------------------------------- fault_free
def test_fault_free_predicate():
    """The one gate every bitwise-legacy fast path hangs off."""
    assert W.fault_free()                      # plain fading, 1 attempt
    assert W.fault_free(perfect=True, arq_max_tx=5, ge_p_gb=0.9)
    assert not W.fault_free(ge_p_gb=0.1)       # GE chain can erase
    assert not W.fault_free(arq_max_tx=2)      # fading + bound can erase
    assert W.fault_free(fading=False, arq_max_tx=2, arq_min_f2=0.5)
    assert not W.fault_free(fading=False, arq_max_tx=2, arq_min_f2=1.5)
    assert not W.fault_free(arq_attempts=3)    # retransmissions possible
    assert W.fault_free(fading=False, arq_attempts=3)


def test_gilbert_elliott_draw_is_key_deterministic_and_bursty():
    """Same key -> same erasure mask; GE off -> mask matches the pure
    bounded-ARQ draw only in distribution, but a bad GE slot erases the
    WHOLE packet window (that's the burstiness)."""
    kw = dict(fading=True, arq_min_f2=0.25, arq_max_tx=3,
              ge_p_gb=0.4, ge_p_bg=0.3)
    k = jax.random.PRNGKey(5)
    a = W.drawn_stacked_tx(k, 4, 6, with_erased=True, **kw)
    b = W.drawn_stacked_tx(k, 4, 6, with_erased=True, **kw)
    np.testing.assert_array_equal(a[0], b[0])
    np.testing.assert_array_equal(a[1], b[1])
    # erased packets always burn the full window
    assert np.all(a[0][a[1]] == 3)
    # a different key moves the mask (the chain is really drawn)
    c = W.drawn_stacked_tx(jax.random.PRNGKey(6), 4, 6,
                           with_erased=True, **kw)
    assert not np.array_equal(a[1], c[1]) or not np.array_equal(a[0], c[0])


# ------------------------------------------------------------ FaultPlan
def test_fault_plan_is_deterministic_and_default_inactive():
    plan = FaultPlan(seed=3, p_outage=0.4, p_dropout=0.3)
    o1, f1 = plan.events(7, 16)
    o2, f2 = plan.events(7, 16)
    np.testing.assert_array_equal(o1, o2)
    np.testing.assert_array_equal(f1, f2)
    # outage and mid-round drop are exclusive; fracs live in (0, 1)
    drops = ~np.isnan(f1)
    assert not np.any(o1 & drops)
    assert np.all((f1[drops] > 0.0) & (f1[drops] < 1.0))
    # the stream varies across cycles
    o3, _ = plan.events(8, 16)
    assert not np.array_equal(o1, o3)
    # a default plan is inactive and draws NOTHING
    idle = FaultPlan()
    assert not idle.active
    oo, ff = idle.events(7, 16)
    assert not oo.any() and np.isnan(ff).all()


# --------------------------------------------------- FL quorum + erasure
def _faulty_fl_wcfg(**kw):
    base = dict(mode="fl", quant_bits=8, n_users=3, local_steps=2)
    base.update(kw)
    return WirelessConfig(**base)


def test_fl_abandoned_round_reanchors_on_broadcast():
    """Every upload erased (bounded ARQ + impossible outage threshold):
    the sync is below any quorum, the round is abandoned, and every
    user's post-round model equals the cycle's broadcast (= the initial
    model) — while the wasted air time is still billed."""
    wcfg = _faulty_fl_wcfg(arq_max_tx=2, arq_min_f2=50.0)
    scheme = FederatedScheme(wcfg, quorum=0.5)
    exp = Experiment(scheme, cycles=1, seed=0,
                     n_train=N_TRAIN, n_test=N_TEST)
    exp.run()
    (rep,) = exp.reports
    assert rep.metrics == {"n_erased_users": 3, "quorum_met": False}
    assert rep.bits > 0 and rep.erased_bits == rep.bits
    # abandoned sync: model re-anchored on the pre-round broadcast
    from repro.runtime.train_step import init_train_state
    from repro.schemes import CFG
    init0 = init_train_state(jax.random.PRNGKey(0), CFG, None,
                             "sgd").trainable["model"]
    post = exp.final_state.train.trainable["model"]
    for a, b in zip(jax.tree.leaves(init0), jax.tree.leaves(post)):
        np.testing.assert_array_equal(np.asarray(a),
                                      np.asarray(b[0]))


def test_fl_graceful_degradation_commits_on_survivors():
    """A lossy-but-not-dead link: the round commits whenever the
    delivered fraction meets quorum; erased uploads carry zero weight;
    fault metrics are present exactly because the fault machinery is
    on."""
    wcfg = _faulty_fl_wcfg(arq_max_tx=2, arq_min_f2=0.4, ge_p_gb=0.2,
                           ge_p_bg=0.6, arq_backoff_s=0.01)
    scheme = FederatedScheme(wcfg, quorum=0.0)
    exp = Experiment(scheme, cycles=2, seed=1,
                     n_train=N_TRAIN, n_test=N_TEST)
    res = exp.run()
    assert all(np.isfinite(l) for l in res.loss)
    for rep in exp.reports:
        assert {"n_erased_users", "quorum_met"} <= set(rep.metrics)
        assert 0 <= rep.metrics["n_erased_users"] <= 3
        assert 0.0 <= rep.erased_bits <= rep.bits
        assert rep.outage_s >= 0.0
        # quorum 0: any single delivered update commits
        assert rep.metrics["quorum_met"] == \
            (rep.metrics["n_erased_users"] < 3)


def test_fl_quorum_one_on_clean_link_is_bitwise_default():
    """quorum=1.0 never triggers on a fault-free link: trajectory and
    billing are bitwise the default scheme's (no fault metric keys
    either — the legacy report shape is untouched)."""
    wcfg = _faulty_fl_wcfg()
    a = Experiment(FederatedScheme(wcfg), cycles=2, seed=0,
                   n_train=N_TRAIN, n_test=N_TEST)
    b = Experiment(FederatedScheme(wcfg, quorum=1.0), cycles=2, seed=0,
                   n_train=N_TRAIN, n_test=N_TEST)
    ra, rb = a.run(), b.run()
    np.testing.assert_array_equal(ra.accuracy, rb.accuracy)
    np.testing.assert_array_equal(ra.loss, rb.loss)
    assert ra.total_bits == rb.total_bits
    for rep in b.reports:
        assert rep.metrics == {} and rep.erased_bits == 0.0
        assert rep.outage_s == 0.0


# ------------------------------------------------- population FaultPlan
def _fleet(base, **kw):
    clients = [ClientSpec.fl(base, name="f0"),
               ClientSpec.fl(base, snr_db=10.0, name="f1"),
               ClientSpec.sl(base, name="s0")]
    return build_scheme(base, clients=clients, **kw)


def test_population_outage_bills_whole_round_as_erased():
    """p_outage=1: every client is unreachable every cycle. No compute,
    full expected round payload billed as attempted-but-erased bits,
    zero energy (the device is dead; the base station kept the slot),
    quorum never met, model frozen."""
    base = WirelessConfig(mode="fl", quant_bits=8)
    scheme = _fleet(base, fault_plan=FaultPlan(seed=0, p_outage=1.0),
                    quorum=0.5)
    exp = Experiment(scheme, cycles=2, seed=0,
                     n_train=N_TRAIN, n_test=N_TEST)
    res = exp.run()
    assert res.accuracy[0] == res.accuracy[1]    # nothing ever trains
    for rep in exp.reports:
        assert rep.metrics["n_erased"] == 3
        assert rep.metrics["quorum_met"] is False
        assert rep.steps == 0
        for i, c in enumerate(rep.clients):
            assert c.status == "erased" and c.steps == 0
            assert c.weight == 0.0 and c.energy_j == 0.0
            assert c.bits == scheme._round_bits_estimate(i)
            assert c.erased_bits == c.bits > 0.0
        assert rep.erased_bits == pytest.approx(
            sum(c.erased_bits for c in rep.clients))


def test_population_midround_dropout_bills_partial_upload():
    """p_dropout=1: every client dies a drawn fraction of the way
    through its upload — partial bits billed (all erased), energy
    billed (those bits were on the air), zero weight, zero steps."""
    base = WirelessConfig(mode="fl", quant_bits=8)
    scheme = _fleet(base, fault_plan=FaultPlan(seed=0, p_dropout=1.0))
    exp = Experiment(scheme, cycles=1, seed=0,
                     n_train=N_TRAIN, n_test=N_TEST)
    exp.run()
    (rep,) = exp.reports
    assert rep.metrics["n_dropped_midround"] == 3
    _, frac = scheme.fault_plan.events(0, 3)
    for i, c in enumerate(rep.clients):
        assert c.status == "dropped_midround"
        est = scheme._round_bits_estimate(i)
        assert c.bits == pytest.approx(frac[i] * est)
        assert 0.0 < c.bits < est
        assert c.erased_bits == c.bits
        assert c.energy_j > 0.0            # partial upload WAS on air
        assert c.weight == 0.0 and c.steps == 0


def test_population_inactive_plan_is_bitwise_neutral():
    """Threading a default FaultPlan + quorum=0 through a fleet leaves
    trajectory, billing, and the report shape bitwise identical to no
    plan at all (no fault metric keys appear)."""
    base = WirelessConfig(mode="fl", quant_bits=8)
    plain = Experiment(_fleet(base), cycles=1, seed=0,
                       n_train=N_TRAIN, n_test=N_TEST)
    idle = Experiment(_fleet(base, fault_plan=FaultPlan(), quorum=0.0),
                      cycles=1, seed=0, n_train=N_TRAIN, n_test=N_TEST)
    rp, ri = plain.run(), idle.run()
    np.testing.assert_array_equal(rp.accuracy, ri.accuracy)
    assert rp.total_bits == ri.total_bits
    for a, b in zip(plain.reports, idle.reports):
        assert [c.bits for c in a.clients] == [c.bits for c in b.clients]
        assert set(a.metrics) == set(b.metrics)
        assert "n_erased" not in b.metrics and "quorum_met" not in b.metrics


def test_population_quorum_validation():
    base = WirelessConfig(mode="fl", quant_bits=8)
    with pytest.raises(ValueError, match="quorum"):
        _fleet(base, quorum=1.5)
    with pytest.raises(ValueError, match="quorum"):
        _fleet(base, quorum=-0.1)


# ------------------------------------------------- SL graceful degradation
def test_fused_sl_survives_erasures_with_finite_loss():
    """Bounded ARQ on the SL activation legs: erased crossings arrive
    as zeros in-graph, training continues, erased legs are billed at
    the full exhausted window and backoff lands in outage_s."""
    wcfg = WirelessConfig(mode="sl", quant_bits=8, arq_max_tx=2,
                          arq_min_f2=1.0, ge_p_gb=0.2, ge_p_bg=0.5,
                          arq_backoff_s=0.02)
    exp = Experiment(build_scheme(wcfg), cycles=1, seed=0,
                     n_train=N_TRAIN, n_test=N_TEST)
    res = exp.run()
    (rep,) = exp.reports
    assert np.isfinite(rep.loss) and 0.0 < res.accuracy[0] < 1.0
    assert rep.erased_bits > 0.0
    assert rep.erased_bits <= rep.bits
    assert rep.outage_s > 0.0


# ---------------------------------------------------- stochastic rounding
def test_stochastic_rounding_unbiased_and_off_by_default():
    """`u=None` (the default) rounds to nearest — bitwise the legacy
    quantizer; with a uniform draw the codeword is unbiased:
    E_u[round(x/s)] == x/s for any x."""
    # one 1.0 element pins the scale at 1/qmax, so the 0.3 block sits
    # BETWEEN two codeword levels (0.3 * 127 = 38.1)
    x = jnp.concatenate([jnp.ones((1,)), jnp.full((4095,), 0.3)])
    q0, s0 = Q.quantize(x, 8)
    q1, s1 = Q.quantize(x, 8, u=None)
    np.testing.assert_array_equal(np.asarray(q0), np.asarray(q1))
    assert float(s0) == float(s1)
    u = jax.random.uniform(jax.random.PRNGKey(0), x.shape)
    qs, ss = Q.quantize(x, 8, u=u)
    # nearest is deterministic; stochastic straddles the two levels
    lv = np.unique(np.asarray(qs)[1:])
    assert len(lv) == 2 and lv[1] == lv[0] + 1
    mean = float(np.asarray(qs)[1:].mean())
    assert mean == pytest.approx(float(x[1] / ss), abs=0.02)


def test_stochastic_rounding_is_packed_only():
    tree = {"w": jax.random.normal(jax.random.PRNGKey(0), (4, 4))}
    with pytest.raises(ValueError, match="packed"):
        W.transmit_tree(jax.random.PRNGKey(1), tree, 8, 10.0,
                        impl="per_leaf", rounding="stochastic")
    with pytest.raises(ValueError, match="rounding"):
        W.transmit_tree(jax.random.PRNGKey(1), tree, 8, 10.0,
                        rounding="banker")
    # Radio: kernel impl + stochastic rounding must refuse, not silently
    # round to nearest
    r = Radio(use_kernel=True, rounding="stochastic")
    with pytest.raises(ValueError, match="packed"):
        r.send_tree(jax.random.PRNGKey(2), tree)


def test_stochastic_rounding_changes_payload_not_billing():
    """Opting in changes the received codewords (same key) but not one
    bit of the accounting — rounding is orthogonal to ARQ/fades."""
    tree = {"w": jax.random.normal(jax.random.PRNGKey(3), (64,))}
    key = jax.random.PRNGKey(4)
    near = Radio(quant_bits=4, snr_db=30.0).send_tree(key, tree)
    stoc = Radio(quant_bits=4, snr_db=30.0,
                 rounding="stochastic").send_tree(key, tree)
    assert not np.array_equal(np.asarray(near.payload["w"]),
                              np.asarray(stoc.payload["w"]))
    assert near.bits == stoc.bits and near.n_tx == stoc.n_tx
    assert near.energy_j == stoc.energy_j


# ------------------------------------------------- FaultPlan.from_log
def test_fault_plan_from_log_replays_exactly(tmp_path):
    """A recorded outage trace replays bit-deterministically: events
    come from the log (path, JSON text, or parsed list — all equal),
    no RNG is touched, the plan seed is irrelevant, and outage wins
    over a same-cycle dropout exactly as in the drawn path."""
    events = [{"cycle": 2, "client": 1, "event": "outage"},
              {"cycle": 2, "client": 0, "event": "dropout", "frac": 0.4},
              {"cycle": 2, "client": 1, "event": "dropout", "frac": 0.9},
              {"cycle": 5, "client": 3, "event": "outage"}]
    p = tmp_path / "outages.json"
    p.write_text(json.dumps(events))
    from_path = FaultPlan.from_log(str(p))
    from_text = FaultPlan.from_log(json.dumps(events))
    from_list = FaultPlan.from_log(events, seed=99)
    assert from_path == from_text
    assert from_path.active and hash(from_path) == hash(from_text)
    for plan in (from_path, from_text, from_list):
        for cycle in range(7):
            out, frac = plan.events(cycle, 4)
            out2, frac2 = plan.events_arrays(cycle, np.full(4, 0.7),
                                             np.full(4, 0.7))
            np.testing.assert_array_equal(out, out2)
            np.testing.assert_array_equal(
                np.isnan(frac), np.isnan(frac2))
            np.testing.assert_array_equal(frac[~np.isnan(frac)],
                                          frac2[~np.isnan(frac2)])
            if cycle == 2:
                assert out.tolist() == [False, True, False, False]
                assert abs(frac[0] - 0.4) < 1e-12
                assert np.isnan(frac[1])        # outage wins
            elif cycle == 5:
                assert out.tolist() == [False, False, False, True]
            else:
                assert not out.any() and np.isnan(frac).all()
    # validation: malformed events are rejected up front
    with pytest.raises(ValueError, match="frac"):
        FaultPlan.from_log([{"cycle": 0, "client": 0,
                             "event": "dropout", "frac": 1.0}])
    with pytest.raises(ValueError, match="unknown fault event"):
        FaultPlan.from_log([{"cycle": 0, "client": 0, "event": "x"}])


def test_fault_plan_from_log_drives_population_deterministically():
    """A replayed plan drives the fleet bit-deterministically run to
    run, and its logged casualties bill exactly like drawn ones: the
    named client's whole expected round payload is attempted-but-erased
    while the unlogged clients train untouched."""
    base = WirelessConfig(mode="fl", quant_bits=8)
    log = [{"cycle": 0, "client": 0, "event": "outage"},
           {"cycle": 0, "client": 2, "event": "dropout", "frac": 0.25}]
    exps = []
    for _ in range(2):
        scheme = _fleet(base, fault_plan=FaultPlan.from_log(log),
                        quorum=0.0)
        exp = Experiment(scheme, cycles=2, seed=0,
                         n_train=N_TRAIN, n_test=N_TEST)
        exp.run()
        exps.append(exp)
    for ra, rb in zip(exps[0].reports, exps[1].reports):
        assert [c.bits for c in ra.clients] == \
               [c.bits for c in rb.clients]
        assert [c.status for c in ra.clients] == \
               [c.status for c in rb.clients]
    rep0, rep1 = exps[0].reports
    scheme = exps[0].scheme
    assert rep0.clients[0].status == "erased"
    assert rep0.clients[0].bits == scheme._round_bits_estimate(0)
    assert rep0.clients[0].erased_bits == rep0.clients[0].bits > 0.0
    assert rep0.clients[1].status not in ("erased", "dropped_midround")
    assert rep0.clients[2].status == "dropped_midround"
    assert rep0.clients[2].bits == pytest.approx(
        0.25 * scheme._round_bits_estimate(2))
    # cycle 1 is outside the log: nobody faults
    assert all(c.status not in ("erased", "dropped_midround")
               for c in rep1.clients)
