"""Channel + quantization unit/property tests (paper Eq. 1-2, 10-11)."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest
from _hyp import given, settings, strategies as st

from repro.core import channel as CH
from repro.core import quantization as Q
from repro.core import energy as EN
from repro.configs.base import WirelessConfig

HS = settings(max_examples=20, deadline=None)


# ----------------------------------------------------------- quantization
@HS
@given(bits=st.integers(2, 16), seed=st.integers(0, 2 ** 16),
       scale=st.floats(1e-3, 1e3))
def test_quant_roundtrip_error_bound(bits, seed, scale):
    """Eq. 1-2: |x - deq(quant(x))| <= S/2 elementwise."""
    x = scale * jax.random.normal(jax.random.PRNGKey(seed), (64,))
    q, s = Q.quantize(x, bits)
    x_hat = Q.dequantize(q, s)
    assert float(jnp.max(jnp.abs(x - x_hat))) <= float(s) / 2 + 1e-7 * scale


@HS
@given(bits=st.integers(2, 16), seed=st.integers(0, 2 ** 16))
def test_quant_offset_codewords_roundtrip(bits, seed):
    """signed levels <-> unsigned codewords is a bijection in range."""
    x = jax.random.normal(jax.random.PRNGKey(seed), (128,))
    q, _ = Q.quantize(x, bits)
    code = Q.quantize_offset(q, bits)
    assert int(code.max()) < 2 ** bits
    q2 = Q.unquantize_offset(code, bits)
    np.testing.assert_array_equal(np.asarray(q), np.asarray(q2))


def test_quantize_ste_gradient_is_identity():
    g = jax.grad(lambda x: jnp.sum(Q.quantize_ste(x, 8) * 3.0))(
        jnp.ones((5,)))
    np.testing.assert_allclose(np.asarray(g), 3.0)


def test_payload_bits():
    x = jnp.zeros((89_673,))
    assert Q.payload_bits(x, 8) == 717_384   # paper: 0.72 Mbit


# ---------------------------------------------------------------- channel
def test_bpsk_ber_analytic_values():
    """Q(sqrt(2 SNR)): at 0 dB -> ~0.0786, at 10 dB -> ~3.9e-6."""
    assert abs(float(CH.bpsk_bit_error_prob(0.0, 1.0)) - 0.0786) < 1e-3
    assert float(CH.bpsk_bit_error_prob(10.0, 1.0)) < 1e-5
    assert float(CH.bpsk_bit_error_prob(-100.0, 1.0)) == pytest.approx(
        0.5, abs=1e-3)


def test_rayleigh_gain_unit_mean():
    keys = jax.random.split(jax.random.PRNGKey(0), 20_000)
    gains = jax.vmap(CH.rayleigh_gain)(keys)
    assert abs(float(gains.mean()) - 1.0) < 0.03     # E|f|^2 = 1
    # exponential distribution: P(g > 1) = 1/e
    assert abs(float((gains > 1.0).mean()) - np.exp(-1)) < 0.02


@HS
@given(n_bits=st.integers(1, 16), seed=st.integers(0, 2 ** 16))
def test_flip_bits_zero_p_identity(n_bits, seed):
    c = jax.random.bits(jax.random.PRNGKey(seed), (64,), jnp.uint32) \
        & jnp.uint32(2 ** n_bits - 1)
    out = CH.flip_bits(jax.random.PRNGKey(seed + 1), c, n_bits, 0.0)
    np.testing.assert_array_equal(np.asarray(c), np.asarray(out))


def test_flip_bits_statistics():
    """Each bit plane flips with probability p independently."""
    n = 200_000
    c = jnp.zeros((n,), jnp.uint32)
    out = CH.flip_bits(jax.random.PRNGKey(0), c, 8, 0.1)
    for b in range(8):
        rate = float(((out >> b) & 1).mean())
        assert abs(rate - 0.1) < 0.01


def test_transmit_quantized_perfect_channel():
    x = jax.random.normal(jax.random.PRNGKey(0), (256,))
    y, diag = CH.transmit_quantized(jax.random.PRNGKey(1), x, 8, 0.0,
                                    perfect=True)
    q, s = Q.quantize(x, 8)
    np.testing.assert_allclose(np.asarray(y),
                               np.asarray(Q.dequantize(q, s)))


def test_transmit_high_snr_no_errors():
    x = jax.random.normal(jax.random.PRNGKey(0), (256,))
    y, diag = CH.transmit_quantized(jax.random.PRNGKey(1), x, 8, 60.0,
                                    fading=False)
    assert float(jnp.max(jnp.abs(y - x))) <= float(
        Q.scale_for(x, 8)) / 2 + 1e-6


def test_transmit_tokens_corrupts_at_low_snr():
    toks = jnp.ones((1000,), jnp.int32) * 500
    rx = CH.transmit_tokens(jax.random.PRNGKey(0), toks, 10_001, -10.0,
                            fading=False)
    assert int((rx != toks).sum()) > 500          # heavy corruption
    assert int(rx.max()) <= 10_000                # clipped to vocab


def test_channel_crossing_gradient_is_clipped_and_quantized():
    """The SL backward leg (Alg. 2): gradient norm after the crossing is
    <= tau (+quantization slack)."""
    x = jax.random.normal(jax.random.PRNGKey(0), (64, 32))
    tau = 0.5

    def f(x):
        y = CH.channel_crossing(x, jax.random.PRNGKey(1), 16, 60.0, False,
                                tau, False)
        return jnp.sum(y * jnp.arange(32, dtype=jnp.float32))

    g = jax.grad(f)(x)
    gnorm = float(jnp.linalg.norm(g))
    assert gnorm <= tau * 1.01


def test_transmit_pytree_counts_bits():
    tree = {"a": jnp.zeros((10, 10)), "b": jnp.zeros((7,))}
    _, bits = CH.transmit_pytree(jax.random.PRNGKey(0), tree, 8, 20.0)
    assert bits == (100 + 7) * 8


# ------------------------------------------------------------------ energy
def test_capacity_monotone_in_snr():
    caps = [EN.channel_capacity(100e3, s, fading=False)
            for s in (0.0, 10.0, 20.0, 30.0)]
    assert all(a < b for a, b in zip(caps, caps[1:]))
    # Shannon-Hartley closed form, no fading: C = B log2(1+SNR)
    assert caps[1] == pytest.approx(100e3 * np.log2(11.0), rel=1e-6)


def test_fading_capacity_below_awgn():
    """Jensen: E[log(1+gX)] < log(1+gE[X]) — Rayleigh costs capacity."""
    c_fade = EN.channel_capacity(100e3, 20.0, fading=True)
    c_awgn = EN.channel_capacity(100e3, 20.0, fading=False)
    assert c_fade < c_awgn


def test_comm_energy_linear_in_payload():
    w = WirelessConfig()
    e1 = EN.comm_energy_j(1e6, w)
    e2 = EN.comm_energy_j(2e6, w)
    assert e2 == pytest.approx(2 * e1, rel=1e-9)


def test_co2_conversion():
    # 1 kWh = 3.6e6 J -> 0.475 kg
    assert EN.co2_kg(3.6e6) == pytest.approx(0.475)
