"""Crash-consistent resume: kill an Experiment at cycle k, resume from
its latest snapshot, and the continued run must reproduce the
uninterrupted run's trajectory AND billing bit-for-bit — accuracy
list, total_bits, and the complete per-round/per-client report trees.
Snapshots are atomic npz files (checkpoint/ckpt.py `save_experiment`);
the data-rng state rides the snapshot so cycle k+1 consumes exactly
the stream it would have seen.
"""
import dataclasses
import glob
import os

import numpy as np
import pytest

from repro.checkpoint import ckpt as CKPT
from repro.configs.base import WirelessConfig
from repro.schemes import (ClientSpec, Experiment, FaultPlan,
                           build_scheme)

N_TRAIN, N_TEST = 2048, 512
CYCLES, KILL_AT = 4, 2


def _fl_faulty():
    return build_scheme(WirelessConfig(
        mode="fl", quant_bits=8, n_users=3, local_steps=2,
        arq_max_tx=2, arq_min_f2=0.4, ge_p_gb=0.2, ge_p_bg=0.6,
        arq_backoff_s=0.01))


def _fleet_faulty():
    base = WirelessConfig(mode="fl", quant_bits=8)
    clients = [ClientSpec.fl(base, name="f0"),
               ClientSpec.fl(base, snr_db=10.0, name="f1"),
               ClientSpec.sl(base, name="s0")]
    return build_scheme(base, clients=clients, quorum=0.34,
                        fault_plan=FaultPlan(seed=0, p_outage=0.3,
                                             p_dropout=0.3))


def _fleet_engine_faulty():
    """The same faulty fleet on the struct-of-arrays engine: its state
    (glob params + per-group stacks + step arrays) snapshots as a
    pytree and its streamed `metrics["fleet"]` summaries are JSON-safe,
    so kill-and-resume must be bit-for-bit like every other scheme."""
    base = WirelessConfig(mode="fl", quant_bits=8)
    clients = [ClientSpec.fl(base, name="f0"),
               ClientSpec.fl(base, snr_db=10.0, name="f1"),
               ClientSpec.sl(base, name="s0")]
    return build_scheme(base, clients=clients, engine="fleet",
                        quorum=0.34,
                        fault_plan=FaultPlan(seed=0, p_outage=0.3,
                                             p_dropout=0.3))


def _sl_faulty():
    return build_scheme(WirelessConfig(
        mode="sl", quant_bits=8, arq_max_tx=2, arq_min_f2=0.7))


def _cl():
    return build_scheme(WirelessConfig(mode="cl", quant_bits=8,
                                       snr_db=15.0))


MAKERS = {"fl-faulty": _fl_faulty, "fleet-faulty": _fleet_faulty,
          "fleet-engine-faulty": _fleet_engine_faulty,
          "sl-faulty": _sl_faulty, "cl": _cl}


def _run(scheme, tmp_path=None, cycles=CYCLES, resume=False, every=0):
    exp = Experiment(
        scheme, cycles=cycles, seed=0, n_train=N_TRAIN, n_test=N_TEST,
        checkpoint_dir=str(tmp_path) if tmp_path is not None else None,
        checkpoint_every=every,
        resume_from=str(tmp_path) if resume else None)
    return exp, exp.run()


@pytest.mark.parametrize("kind", sorted(MAKERS))
def test_kill_and_resume_is_bit_for_bit(kind, tmp_path):
    """Acceptance: straight run == (run killed after k cycles, resumed
    to the end) on every scheme family, including faulty links, a
    FaultPlan+quorum fleet, and CL (whose init-time corpus upload must
    not be double-counted on resume)."""
    make = MAKERS[kind]
    e1, r1 = _run(make())                              # uninterrupted
    e2, _ = _run(make(), tmp_path, cycles=KILL_AT, every=1)   # "crashes"
    assert CKPT.latest_experiment_cycle(str(tmp_path)) == KILL_AT
    e3, r3 = _run(make(), tmp_path, resume=True)       # resumed to end

    np.testing.assert_array_equal(r1.accuracy, r3.accuracy)
    np.testing.assert_array_equal(r1.loss, r3.loss)
    assert r1.total_bits == r3.total_bits
    assert [dataclasses.asdict(r) for r in e1.reports] \
        == [dataclasses.asdict(r) for r in e3.reports]
    # the resumed run really skipped the first k cycles' snapshots
    assert len(e3.reports) == CYCLES
    # atomic writes: no tmp files survive
    assert not glob.glob(os.path.join(str(tmp_path), "*.tmp*"))


def test_latest_experiment_cycle_picks_max(tmp_path):
    assert CKPT.latest_experiment_cycle(str(tmp_path)) is None
    for c in (1, 3, 2):
        CKPT.save_experiment(str(tmp_path), c, {"w": np.zeros(2)},
                             {"cycle": c})
    assert CKPT.latest_experiment_cycle(str(tmp_path)) == 3
    train, meta = CKPT.load_experiment(str(tmp_path),
                                       {"w": np.ones(2)})
    assert meta["cycle"] == 3
    np.testing.assert_array_equal(np.asarray(train["w"]), 0.0)


def test_snapshot_roundtrips_scalars_and_arrays(tmp_path):
    """Python-scalar template leaves come back as the SAME python type
    (a resumed step counter must not silently become np.int64), arrays
    come back exactly, and shape mismatches fail loudly."""
    train = {"w": np.arange(6, dtype=np.float32).reshape(2, 3),
             "step": 7, "lr": 0.125}
    path = CKPT.save_experiment(str(tmp_path), 4, train,
                                {"cycle": 4, "note": "x"})
    out, meta = CKPT.load_experiment(path, train)
    assert type(out["step"]) is int and out["step"] == 7
    assert type(out["lr"]) is float and out["lr"] == 0.125
    np.testing.assert_array_equal(np.asarray(out["w"]), train["w"])
    assert meta == {"cycle": 4, "note": "x"}
    with pytest.raises(Exception):
        CKPT.load_experiment(path, {"w": np.zeros((3, 3)),
                                    "step": 0, "lr": 0.0})


def test_checkpoint_validations(tmp_path):
    with pytest.raises(ValueError, match="checkpoint_dir"):
        Experiment(_cl(), cycles=1, checkpoint_every=1).run()
    # the two-party SL protocol holds live sessions — not snapshottable
    sl2 = build_scheme(WirelessConfig(mode="sl", quant_bits=8),
                       protocol="two_party")
    with pytest.raises(ValueError, match="two-party"):
        Experiment(sl2, cycles=1, checkpoint_dir=str(tmp_path),
                   checkpoint_every=1).run()
